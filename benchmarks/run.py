"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).
``--small`` runs the reduced corpus (CI); default is the full bench corpus.
The roofline/dry-run figures live in launch/dryrun.py + launch/roofline.py
(they need the 512-device flag and are therefore a separate entry point).
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="reduced corpus (CI-sized)")
    ap.add_argument("--tables", default="1,3,4,5,6,7",
                    help="comma-separated table numbers to run; add 'smoke' "
                         "for the JSON smoke bench (BENCH_spmv.json)")
    args = ap.parse_args(argv)
    tables = {t.strip() for t in args.tables.split(",")}
    t0 = time.time()

    if "smoke" in tables:
        from benchmarks import bench_spmv_smoke
        bench_spmv_smoke.main([])

    from benchmarks import table1_peak_model, table3_csr_hybrid, \
        table4_rgcsr_groups, table5_comparison, table6_pathological, \
        table7_ordering

    if "1" in tables:
        table1_peak_model.run()
    if "3" in tables:
        table3_csr_hybrid.run(small_only=args.small)
    if "4" in tables:
        table4_rgcsr_groups.run(small_only=args.small)
    if "5" in tables:
        table5_comparison.run(small_only=args.small)
    if "6" in tables:
        table6_pathological.run(scale=64 if args.small else 16)
    if "7" in tables:
        table7_ordering.run(scale=64 if args.small else 16)
    print(f"# benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
