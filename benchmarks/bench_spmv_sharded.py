"""Multi-device smoke benchmark: row-sharded RgCSR SpMV on 8 fake devices.

Runs in CI without TPUs by forcing 8 host devices (the flag is set below,
before any jax import, unless the environment already provides one):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src:. python benchmarks/bench_spmv_sharded.py \\
        --out BENCH_spmv_sharded.json

Per matrix it builds the single-device plan and the 8-shard stacked plan at
the same config (cps=2, block + heuristic-spill adaptive), verifies the
shard_map result against the dense product, and records the tentpole's
acceptance figures: **per-shard stored slots and grid steps vs 1/D of the
single-device plan** (the ~1/D shrink), the split-mode remote-column count
per shard (the communicated x entries of arXiv:1112.5588's local/remote
decomposition — usually tiny), and µs/call for the replicated and split
paths.  Absolute µs are CPU interpret-mode (every shard's kernel executes
sequentially on the host), so only the *structural* figures are meaningful;
timing is recorded to keep the path exercised end to end.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse          # noqa: E402
import json              # noqa: E402
import platform          # noqa: E402
import sys               # noqa: E402
from typing import Dict  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core.formats import RgCSR, ShardedRgCSR   # noqa: E402
from repro.core.suite import generate                # noqa: E402
from repro.core.timing import time_us                # noqa: E402
from repro.kernels import autotune                   # noqa: E402
from repro.kernels import ops as kops                # noqa: E402
from repro.sharding import Partitioner               # noqa: E402

# n=1024 on 8 devices → 128 rows/shard = exactly one full 128-lane group,
# so the ~1/D shrink is visible without the partial-group lane floor that
# smaller matrices hit (DESIGN.md §5 discusses the same floor at n=64).
FAMILIES = (("uniform", 1024), ("banded", 1024), ("powerlaw", 1024),
            ("circuit", 1024))


def _heuristic_spill(a: np.ndarray) -> int:
    cands = autotune.spill_threshold_candidates((a != 0).sum(axis=1))
    return cands[1] if len(cands) > 1 else 0


def bench_one(family: str, n: int, mesh, axis: str, d: int,
              repeats: int) -> Dict:
    a = generate(family, n, seed=0)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(a.shape[1])
                    .astype(np.float32))
    spill = _heuristic_spill(a)
    single = kops.make_plan(RgCSR.from_dense(a), chunks_per_step=2)
    sm = ShardedRgCSR.from_dense(a, n_shards=d)
    row: Dict = {"n": n, "family": family, "nnz": int((a != 0).sum()),
                 "single": {"stored_slots": single.stored_slots,
                            "grid_steps": single.num_steps},
                 "sharded": {}}
    for label, ordering, th, x_mode in (
            ("block_replicated", "block", 0, "replicated"),
            ("block_split", "block", 0, "split"),
            ("adaptive_split", "adaptive", spill, "split")):
        plan = kops.get_sharded_plan(sm, chunks_per_step=2,
                                     ordering=ordering, spill_threshold=th,
                                     x_mode=x_mode)
        y = np.asarray(kops.sharded_rgcsr_spmv(plan, x, mesh=mesh,
                                               axis=axis))
        np.testing.assert_allclose(y, a @ np.asarray(x), rtol=1e-4,
                                   atol=1e-4)
        us = time_us(lambda p, v: kops.sharded_rgcsr_spmv(
            p, v, mesh=mesh, axis=axis), plan, x, repeats=repeats, warmup=1)
        slots_max = max(plan.shard_stored_slots)
        steps_max = max(plan.shard_num_steps)
        row["sharded"][label] = {
            "us": round(us, 2),
            "shard_stored_slots_max": slots_max,
            "shard_grid_steps_max": steps_max,
            # the ~1/D acceptance ratios (1.0 = a perfect 1/D shrink)
            "slots_shrink_vs_single": round(
                single.stored_slots / max(slots_max * d, 1), 3),
            "steps_shrink_vs_single": round(
                single.num_steps / max(steps_max * d, 1), 3),
            "remote_cols_per_shard": list(plan.shard_remote_cols),
            "spill_threshold": th,
            "padded_slot_fraction": round(plan.padded_slot_fraction, 4),
        }
        print(f"{family}/{label},{us:.2f},slots_max={slots_max},"
              f"steps_max={steps_max},remote={max(plan.shard_remote_cols)}")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_spmv_sharded.json")
    ap.add_argument("--repeats", type=int, default=2)
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    if n_dev < 8:
        print(f"# need 8 devices, got {n_dev} — set XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8", file=sys.stderr)
        return 1
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    axis = Partitioner(mesh, "decode").spmv_shard_axis()
    assert axis == "model", axis
    d = int(mesh.shape[axis])

    matrices = {f"{fam}_{n}": bench_one(fam, n, mesh, axis, d, args.repeats)
                for fam, n in FAMILIES}
    rows = list(matrices.values())

    def geomean(vals):
        return round(float(np.exp(np.mean(
            np.log(np.maximum(vals, 1e-9))))), 3)

    remote = [max(r["sharded"]["block_split"]["remote_cols_per_shard"])
              for r in rows]
    summary = {
        "n_devices": d,
        "mesh_axis": axis,
        # geomean of single/(per_shard_max·D): 1.0 = exactly 1/D per shard
        "slots_shrink_geomean": geomean(
            [r["sharded"]["block_replicated"]["slots_shrink_vs_single"]
             for r in rows]),
        "steps_shrink_geomean": geomean(
            [r["sharded"]["block_replicated"]["steps_shrink_vs_single"]
             for r in rows]),
        # adaptive per-shard grouping recovers the shrink skewed profiles
        # lose to the one heavy shard (its group sizes to its own max)
        "slots_shrink_geomean_adaptive": geomean(
            [r["sharded"]["adaptive_split"]["slots_shrink_vs_single"]
             for r in rows]),
        "max_remote_cols": int(max(remote)),
    }
    doc = {"meta": {"backend": jax.default_backend(),
                    "python": platform.python_version(),
                    "repeats": args.repeats},
           "matrices": matrices, "summary": summary}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {args.out}: per-shard slots shrink "
          f"{summary['slots_shrink_geomean']}x of ideal 1/{d}, steps "
          f"{summary['steps_shrink_geomean']}x, max remote cols "
          f"{summary['max_remote_cols']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
