"""Multi-device smoke benchmark: row-sharded RgCSR SpMV on 8 fake devices.

Runs in CI without TPUs by forcing 8 host devices (the flag is set below,
before any jax import, unless the environment already provides one):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src:. python benchmarks/bench_spmv_sharded.py \\
        --out BENCH_spmv_sharded.json

Per matrix it builds the single-device plan and the 8-shard stacked plan at
a fixed config (cps=2, block + heuristic-spill adaptive) **plus the
per-shard autotuned plan** (DESIGN.md §12: each shard's own
``(chunks_per_step, ordering, spill_threshold)`` winner), verifies every
shard_map result against the dense product, and records the acceptance
figures: **per-shard stored slots and grid steps vs 1/D of the
single-device plan** (the ~1/D shrink), the split-mode **exchange volume**
of the §12 plan-driven sparse collective — received x entries per shard,
asserted equal to that shard's plan-time remote column count, vs the
``n_cols`` entries the old all_gather moved per device — and µs/call for
the replicated, split and per-shard-tuned paths.  Absolute µs are CPU
interpret-mode (every shard's kernel executes sequentially on the host), so
only the *structural* figures are meaningful; timing is recorded to keep
the path exercised end to end and to let the CI gate compare within-run
normalized ratios (benchmarks/check_bench_regression.py --sharded-*).
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse          # noqa: E402
import json              # noqa: E402
import platform          # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
from typing import Dict  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core.formats import RgCSR, ShardedRgCSR   # noqa: E402
from repro.core.suite import generate                # noqa: E402
from repro.kernels import autotune                   # noqa: E402
from repro.kernels import ops as kops                # noqa: E402
from repro.sharding import Partitioner               # noqa: E402

# n=1024 on 8 devices → 128 rows/shard = exactly one full 128-lane group,
# so the ~1/D shrink is visible without the partial-group lane floor that
# smaller matrices hit (DESIGN.md §5 discusses the same floor at n=64).
FAMILIES = (("uniform", 1024), ("banded", 1024), ("powerlaw", 1024),
            ("circuit", 1024))


def _heuristic_spill(a: np.ndarray) -> int:
    cands = autotune.spill_threshold_candidates((a != 0).sum(axis=1))
    return cands[1] if len(cands) > 1 else 0


def bench_one(family: str, n: int, mesh, axis: str, d: int,
              repeats: int) -> Dict:
    a = generate(family, n, seed=0)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(a.shape[1])
                    .astype(np.float32))
    spill = _heuristic_spill(a)
    single = kops.make_plan(RgCSR.from_dense(a), chunks_per_step=2)
    sm = ShardedRgCSR.from_dense(a, n_shards=d)
    # §12 per-shard tuning: every shard searches (cps, ordering, spill)
    # over its own local-column block (what split-mode grouped storage
    # actually holds); the signature memo dedupes the light shards
    shard_results = autotune.autotune_spmv_per_shard(a, d, repeats=repeats,
                                                     x_mode="split")
    shard_cfgs = autotune.harmonize_shard_winners(shard_results)
    winners = [[c.chunks_per_step, c.ordering, c.spill_threshold]
               for c in shard_cfgs]
    row: Dict = {"n": n, "family": family, "nnz": int((a != 0).sum()),
                 "single": {"stored_slots": single.stored_slots,
                            "grid_steps": single.num_steps},
                 "sharded": {}}
    variants = (
        ("block_replicated", dict(chunks_per_step=2, ordering="block",
                                  spill_threshold=0, x_mode="replicated")),
        ("block_split", dict(chunks_per_step=2, ordering="block",
                             spill_threshold=0, x_mode="split")),
        ("adaptive_split", dict(chunks_per_step=2, ordering="adaptive",
                                spill_threshold=spill, x_mode="split")),
        ("tuned_per_shard", dict(x_mode="split",
                                 shard_configs=shard_cfgs)))
    plans = {label: kops.get_sharded_plan(sm, **kwargs)
             for label, kwargs in variants}
    # correctness + jit warmup for every variant before any timing
    for label, plan in plans.items():
        y = np.asarray(kops.sharded_rgcsr_spmv(plan, x, mesh=mesh,
                                               axis=axis))
        np.testing.assert_allclose(y, a @ np.asarray(x), rtol=1e-4,
                                   atol=1e-4)
    # timing rounds are INTERLEAVED across variants: fake-device shard_map
    # dispatch jitter drifts over seconds on a loaded host, so timing each
    # variant in its own contiguous block would bias whole labels — the
    # within-round rotation keeps the variant *comparison* fair, which is
    # the number the tuned-vs-fixed figures and the CI gate consume
    times: Dict[str, list] = {label: [] for label, _ in variants}
    for _ in range(max(repeats, 3)):
        for label, plan in plans.items():
            t0 = time.perf_counter()
            jax.block_until_ready(kops.sharded_rgcsr_spmv(
                plan, x, mesh=mesh, axis=axis))
            times[label].append((time.perf_counter() - t0) * 1e6)
    for label, kwargs in variants:
        plan = plans[label]
        us = float(np.median(times[label]))
        slots_max = max(plan.shard_stored_slots)
        steps_max = max(plan.shard_num_steps)
        # the acceptance bound: the sparse collective moves exactly each
        # shard's plan-time remote set — never more
        assert plan.shard_exchange_recv_cols == plan.shard_remote_cols
        entry = {
            "us": round(us, 2),
            "shard_stored_slots_max": slots_max,
            "shard_grid_steps_max": steps_max,
            # the ~1/D acceptance ratios (1.0 = a perfect 1/D shrink)
            "slots_shrink_vs_single": round(
                single.stored_slots / max(slots_max * d, 1), 3),
            "steps_shrink_vs_single": round(
                single.num_steps / max(steps_max * d, 1), 3),
            "remote_cols_per_shard": list(plan.shard_remote_cols),
            # §12 sparse-collective exchange volume (all zeros when
            # replicated: that mode communicates nothing by construction)
            "exchange_recv_cols_per_shard": list(
                plan.shard_exchange_recv_cols),
            "exchange_bytes_per_shard": list(plan.shard_exchange_bytes),
            "exchange_padded_recv_cols": plan.exchange_padded_recv_cols,
            "spill_threshold": kwargs.get("spill_threshold", 0),
            "padded_slot_fraction": round(plan.padded_slot_fraction, 4),
        }
        if label == "tuned_per_shard":
            entry["shard_winner_configs"] = winners
            entry["winners_differ_across_shards"] = \
                len({tuple(w) for w in winners}) > 1
            entry["kernel_chunks_per_step"] = plan.chunks_per_step
        row["sharded"][label] = entry
        print(f"{family}/{label},{us:.2f},slots_max={slots_max},"
              f"steps_max={steps_max},"
              f"xchg={max(plan.shard_exchange_recv_cols)}")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_spmv_sharded.json")
    ap.add_argument("--repeats", type=int, default=2)
    args = ap.parse_args(argv)

    # same clock for tuner and in-run normalizers (see bench_spmv_smoke:
    # the gate's normalized ratios need one timing source end to end);
    # recorded in meta.timing_source
    autotune.set_timing_source("wallclock")

    n_dev = len(jax.devices())
    if n_dev < 8:
        print(f"# need 8 devices, got {n_dev} — set XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8", file=sys.stderr)
        return 1
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    axis = Partitioner(mesh, "decode").spmv_shard_axis()
    assert axis == "model", axis
    d = int(mesh.shape[axis])

    matrices = {f"{fam}_{n}": bench_one(fam, n, mesh, axis, d, args.repeats)
                for fam, n in FAMILIES}
    rows = list(matrices.values())

    def geomean(vals):
        return round(float(np.exp(np.mean(
            np.log(np.maximum(vals, 1e-9))))), 3)

    remote = [max(r["sharded"]["block_split"]["remote_cols_per_shard"])
              for r in rows]
    xchg_bytes = [max(r["sharded"]["block_split"]["exchange_bytes_per_shard"])
                  for r in rows]
    # per-shard tuning pays when the tuned-split plan beats the best fixed
    # single-config split schedule of the same run.  The decisive figures
    # are STRUCTURAL (stacked grid steps and padded slots — deterministic
    # plan properties, and the quantities the schedule knobs actually
    # optimize); µs is reported but informational only: each variant is a
    # separately compiled shard_map executable and on the fake-device CPU
    # host per-executable dispatch varies ~2x run to run, swamping the
    # kernel-level differences the tuner targets.
    tuned_vs_fixed = {}
    for name, r in matrices.items():
        sh = r["sharded"]
        fixed_us = min(sh["block_split"]["us"], sh["adaptive_split"]["us"])
        fixed_steps = min(sh["block_split"]["shard_grid_steps_max"],
                          sh["adaptive_split"]["shard_grid_steps_max"])
        fixed_slots = min(sh["block_split"]["shard_stored_slots_max"],
                          sh["adaptive_split"]["shard_stored_slots_max"])
        t = sh["tuned_per_shard"]
        steps, slots = t["shard_grid_steps_max"], t["shard_stored_slots_max"]
        tuned_vs_fixed[name] = {
            "tuned_us_over_best_fixed_split": round(
                t["us"] / max(fixed_us, 1e-9), 3),
            "tuned_steps_max": steps,
            "best_fixed_steps_max": fixed_steps,
            "tuned_slots_max": slots,
            "best_fixed_slots_max": fixed_slots,
            # never structurally worse, strictly better on >= one axis
            "structurally_improves": (steps <= fixed_steps
                                      and slots <= fixed_slots
                                      and (steps < fixed_steps
                                           or slots < fixed_slots)),
            "winners_differ": t["winners_differ_across_shards"],
        }
    skewed_improved = [
        name for name, r in matrices.items()
        if r["family"] in ("powerlaw", "circuit")
        and tuned_vs_fixed[name]["structurally_improves"]
        and tuned_vs_fixed[name]["winners_differ"]]
    summary = {
        "n_devices": d,
        "mesh_axis": axis,
        # geomean of single/(per_shard_max·D): 1.0 = exactly 1/D per shard
        "slots_shrink_geomean": geomean(
            [r["sharded"]["block_replicated"]["slots_shrink_vs_single"]
             for r in rows]),
        "steps_shrink_geomean": geomean(
            [r["sharded"]["block_replicated"]["steps_shrink_vs_single"]
             for r in rows]),
        # adaptive per-shard grouping recovers the shrink skewed profiles
        # lose to the one heavy shard (its group sizes to its own max)
        "slots_shrink_geomean_adaptive": geomean(
            [r["sharded"]["adaptive_split"]["slots_shrink_vs_single"]
             for r in rows]),
        "max_remote_cols": int(max(remote)),
        # §12 sparse collective: worst per-device exchange, and the factor
        # vs the n_cols·itemsize every device paid under the all_gather
        "max_exchange_bytes_per_shard": int(max(xchg_bytes)),
        "allgather_bytes_per_shard": int(
            max(r["n"] for r in rows) * 4),
        "tuned_vs_fixed_split": tuned_vs_fixed,
        "skewed_improved_by_per_shard_winners": skewed_improved,
    }
    doc = {"meta": {"backend": jax.default_backend(),
                    "python": platform.python_version(),
                    "repeats": args.repeats,
                    # per-shard autotune timing provenance (DESIGN.md §13.4)
                    "timing_source": autotune.timing_source()},
           "matrices": matrices, "summary": summary}
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {args.out}: per-shard slots shrink "
          f"{summary['slots_shrink_geomean']}x of ideal 1/{d}, steps "
          f"{summary['steps_shrink_geomean']}x, max remote cols "
          f"{summary['max_remote_cols']}, max exchange "
          f"{summary['max_exchange_bytes_per_shard']} B/device (all_gather "
          f"paid {summary['allgather_bytes_per_shard']} B), per-shard "
          f"winners improved: {skewed_improved}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
