"""Shared benchmark utilities: timing, corpus, CSV emission.

Output convention (benchmarks/run.py): every row prints
``name,us_per_call,derived`` — `derived` is the table-specific figure
(GFLOPS, fill %, speed-up …).

Measured numbers are CPU (this container); the TPU-target figures come from
the bandwidth model (repro.core.analyze.modeled_gflops with TPU_V5E), which
is exactly the paper's §3.4 estimation methodology transplanted to the
target chip.  Relative format behaviour (the paper's actual claims) is
measured; absolute GPU GFLOPS are not reproducible on CPU and are reported
via the model only.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

from repro.core import from_dense, spmv
from repro.core.suite import MatrixSpec, corpus
from repro.core.timing import time_us  # noqa: F401  (re-export; shared harness)

__all__ = ["time_us", "bench_corpus", "spmv_gflops_measured",
           "spmv_us_kernel", "emit"]


_JITTED: Dict[type, Callable] = {}


def _jit_spmv(mat):
    cls = type(mat)
    if cls not in _JITTED:
        _JITTED[cls] = jax.jit(spmv)
    return _JITTED[cls]


def spmv_gflops_measured(mat, x, repeats: int = 5) -> Tuple[float, float]:
    """Measured SpMV throughput.  Returns ``(gflops, us_per_call)``."""
    us = time_us(_jit_spmv(mat), mat, x, repeats=repeats)
    return 2.0 * mat.nnz / (us * 1e-6) / 1e9, us


def spmv_us_kernel(mat, x, *, chunks_per_step: int = 1, repeats: int = 5,
                   ordering: str = "block", spill_threshold: int = 0,
                   interpret: bool | None = None) -> Tuple[float, int]:
    """µs/call of the Pallas RgCSR kernel through the process-wide PlanCache
    (plan built once, not per call).  Returns ``(us_per_call, grid_steps)``.
    ``ordering``/``spill_threshold`` select the adaptive regrouped plan
    (DESIGN.md §5); timing includes its fused gather/spill epilogue.
    """
    from repro.kernels import ops as kops
    plan = kops.get_plan(mat, chunks_per_step=chunks_per_step,
                         ordering=ordering, spill_threshold=spill_threshold)
    us = time_us(lambda p, v: kops.rgcsr_spmv(p, v, interpret=interpret),
                 plan, x, repeats=repeats)
    return us, plan.num_steps


def bench_corpus(small_only: bool = False) -> List[MatrixSpec]:
    if small_only:
        return corpus(small_n=(64, 256), large_n=(1024,), seeds=(0,))
    return corpus(small_n=(64, 256, 512, 1024), large_n=(2048, 4096),
                  seeds=(0,))


# the paper's small/large boundary, scaled with the corpus (DESIGN.md §10)
LARGE_BOUNDARY = 2048


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.2f},{derived}")
