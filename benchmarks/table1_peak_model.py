"""Table 1 — peak-performance model of RgCSR SpMV.

Reproduces the paper's closed form (bytes per nonzero → GFLOPS at bandwidth
m) for the GTX280 (validating our model against the paper's own numbers:
23.5 / 14.1 uncached, 35.25 / 23.5 cached, §3.4 Table 1) and emits the TPU
v5e targets used throughout EXPERIMENTS.md.  On TPU the precision pair is
(bf16, fp32) — same 2:1 byte ratio as the paper's (single, double)
(DESIGN.md §2).
"""
from __future__ import annotations

from repro.core.analyze import GTX280, TPU_V5E, peak_model_gflops
from benchmarks.common import emit

# the paper's Table 1 (GTX280, 141 GB/s)
PAPER_TABLE1 = {
    ("single", False): 23.5,
    ("double", False): 14.1,
    ("single", True): 35.25,
    ("double", True): 23.5,
}


def run():
    print("# table1: SpMV peak model — name,us_per_call,derived(GFLOPS)")
    ok = True
    for (prec, cached), expected in PAPER_TABLE1.items():
        nbytes = 4 if prec == "single" else 8
        got = peak_model_gflops(GTX280, nbytes, cached)
        emit(f"table1/gtx280/{prec}/{'cached' if cached else 'uncached'}",
             0.0, f"{got:.2f}")
        ok &= abs(got - expected) / expected < 0.02
    emit("table1/model_matches_paper", 0.0, ok)
    for prec, nbytes in (("bf16", 2), ("fp32", 4)):
        for cached in (False, True):
            got = peak_model_gflops(TPU_V5E, nbytes, cached)
            emit(f"table1/tpu_v5e/{prec}/"
                 f"{'cached' if cached else 'uncached'}", 0.0, f"{got:.2f}")
    return ok


if __name__ == "__main__":
    run()
