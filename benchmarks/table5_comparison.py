"""Table 5 — CSR / Hybrid / RgCSR win-rates and relative speed-ups.

Paper claims reproduced (complete set, single precision):
* RgCSR faster than Hybrid on most matrices (paper: 77.14%),
* RgCSR/Hybrid average speed-up > 1 (paper: 2.55),
* the advantage is larger on small matrices (84.43%) than large (62.57%).

RgCSR runs at the paper's best group size (128).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import LARGE_BOUNDARY, bench_corpus, emit, \
    spmv_gflops_measured
from repro.core import from_dense


def run(small_only: bool = False):
    print("# table5: format comparison — name,us_per_call,derived")
    rows = []
    for spec in bench_corpus(small_only):
        dense = spec.build()
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            dense.shape[1]).astype(np.float32))
        rec = {"name": spec.name, "n": spec.n}
        for fmt, kw in (("csr", {}), ("hybrid", {}),
                        ("rgcsr", {"group_size": 128})):
            mat = from_dense(dense, fmt, **kw)
            gf, us = spmv_gflops_measured(mat, x)
            rec[fmt] = gf
        rows.append(rec)
        emit(f"table5/{spec.name}", 0.0,
             f"csr={rec['csr']:.3f}|hyb={rec['hybrid']:.3f}"
             f"|rg={rec['rgcsr']:.3f}")

    for subset, sel in (("complete", rows),
                        ("small", [r for r in rows if r["n"] < LARGE_BOUNDARY]),
                        ("large", [r for r in rows if r["n"] >= LARGE_BOUNDARY])):
        if not sel:
            continue
        n = len(sel)
        hyb_vs_csr = 100 * sum(r["hybrid"] > r["csr"] for r in sel) / n
        rg_vs_csr = 100 * sum(r["rgcsr"] > r["csr"] for r in sel) / n
        rg_vs_hyb = 100 * sum(r["rgcsr"] > r["hybrid"] for r in sel) / n
        ratio = np.mean([r["rgcsr"] / max(r["hybrid"], 1e-9) for r in sel])
        emit(f"table5/{subset}/hyb_faster_than_csr_pct", 0.0, f"{hyb_vs_csr:.1f}")
        emit(f"table5/{subset}/rg_faster_than_csr_pct", 0.0, f"{rg_vs_csr:.1f}")
        emit(f"table5/{subset}/rg_faster_than_hyb_pct", 0.0, f"{rg_vs_hyb:.1f}")
        emit(f"table5/{subset}/avg_rg_over_hyb", 0.0, f"{ratio:.2f}")
    return rows


if __name__ == "__main__":
    run()
