"""Table 6 — the four characteristic matrices (synthetic twins).

Paper claim reproduced: RgCSR wins decisively on the low-row-variance
matrices (fd18, G2_circuit) and loses catastrophically on the
dense-row matrices (trans4, Raj1) where its fill explodes (paper:
2,118% / 938% artificial zeros) — the format's "true weak point" (§4.4.2).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, spmv_gflops_measured
from repro.core import from_dense
from repro.core.analyze import row_stats
from repro.core.suite import paper_twins

# paper Table 6 reference (double precision GFLOPS on GTX280)
PAPER = {
    "fd18_twin": {"rgcsr": 4.69, "hybrid": 0.95},
    "g2_circuit_twin": {"rgcsr": 9.36, "hybrid": 2.5},
    "trans4_twin": {"rgcsr": 0.019, "hybrid": 2.0},
    "raj1_twin": {"rgcsr": 0.058, "hybrid": 2.2},
}


def run(scale: int = 16):
    print("# table6: pathological matrices — name,us_per_call,derived")
    results = {}
    for name, dense in paper_twins(scale=scale).items():
        st = row_stats(dense)
        emit(f"table6/{name}/rows", 0.0, st["rows"])
        emit(f"table6/{name}/row_nnz_max_mean_min", 0.0,
             f"{st['row_nnz_max']}|{st['row_nnz_mean']:.2f}|"
             f"{st['row_nnz_min']}")
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            dense.shape[1]).astype(np.float32))
        rec = {}
        for fmt, kw in (("rgcsr", {"group_size": 128}), ("hybrid", {}),
                        ("csr", {})):
            mat = from_dense(dense, fmt, **kw)
            gf, us = spmv_gflops_measured(mat, x)
            rec[fmt] = gf
            if fmt == "rgcsr":
                emit(f"table6/{name}/rgcsr_fill", 0.0,
                     f"{mat.fill_ratio():.1f}%")
            emit(f"table6/{name}/{fmt}", us, f"{gf:.4f}")
        # the paper's qualitative claim: sign of (rgcsr - hybrid) matches
        paper_sign = PAPER[name]["rgcsr"] > PAPER[name]["hybrid"]
        ours_sign = rec["rgcsr"] > rec["hybrid"]
        emit(f"table6/{name}/winner_matches_paper", 0.0,
             paper_sign == ours_sign)
        results[name] = rec
    return results


if __name__ == "__main__":
    run()
