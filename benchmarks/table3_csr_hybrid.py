"""Table 3 — common CSR vs Hybrid over the matrix corpus.

Paper claims reproduced (relative behaviour):
* Hybrid ≫ CSR on large matrices (paper: avg speed-up 5.59 single),
* Hybrid ≈ or < CSR on small matrices (paper: avg 0.97 — "does not make
  sense to use the Hybrid format for the small matrices").

Statistics: min/max/avg measured SpMV throughput per set (complete /
small / large, boundary scaled per DESIGN.md §10), plus the TPU-modeled
GFLOPS from each format's byte footprint.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import LARGE_BOUNDARY, bench_corpus, emit, \
    spmv_gflops_measured
from repro.core import from_dense
from repro.core.analyze import modeled_gflops
import jax.numpy as jnp

FORMATS = ("csr", "hybrid")


def run(small_only: bool = False):
    print("# table3: CSR vs Hybrid — name,us_per_call,derived(GFLOPS)")
    rows = []
    for spec in bench_corpus(small_only):
        dense = spec.build()
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            dense.shape[1]).astype(np.float32))
        rec = {"name": spec.name, "n": spec.n}
        for fmt in FORMATS:
            mat = from_dense(dense, fmt)
            gf, us = spmv_gflops_measured(mat, x)
            rec[fmt] = gf
            rec[fmt + "_model"] = modeled_gflops(mat)
            emit(f"table3/{spec.name}/{fmt}", us, f"{gf:.3f}")
        rec["speedup"] = rec["hybrid"] / max(rec["csr"], 1e-9)
        rows.append(rec)

    for subset, sel in (("complete", rows),
                        ("small", [r for r in rows if r["n"] < LARGE_BOUNDARY]),
                        ("large", [r for r in rows if r["n"] >= LARGE_BOUNDARY])):
        if not sel:
            continue
        sp = np.array([r["speedup"] for r in sel])
        for fmt in FORMATS:
            g = np.array([r[fmt] for r in sel])
            emit(f"table3/{subset}/{fmt}_avg_gflops", 0.0, f"{g.mean():.3f}")
        emit(f"table3/{subset}/speedup_min", 0.0, f"{sp.min():.3f}")
        emit(f"table3/{subset}/speedup_max", 0.0, f"{sp.max():.3f}")
        emit(f"table3/{subset}/speedup_avg", 0.0, f"{sp.mean():.3f}")
    return rows


if __name__ == "__main__":
    run()
