"""Table 7 — effect of row ordering on RgCSR fill + throughput.

Paper claims reproduced:
* descending row-length ordering is near-optimal for fill (paper: fd18
  2.76% → 0.34%, Raj1 938% → 189%),
* the bandwidth-reducing ordering (paper: AMD; here: RCM, DESIGN.md §9)
  helps x-locality but pads more than descending,
* ordering cannot rescue the dense-row pathologies (trans4 stays >1000%).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, spmv_gflops_measured
from repro.core import from_dense
from repro.core.ordering import ORDERINGS, permute_rows
from repro.core.suite import paper_twins


def run(scale: int = 16):
    print("# table7: ordering effects — name,us_per_call,derived")
    results = {}
    for name, dense in paper_twins(scale=scale).items():
        fills = {}
        for oname, ofn in ORDERINGS.items():
            perm = ofn(dense)
            reordered = permute_rows(dense, perm)
            mat = from_dense(reordered, "rgcsr", group_size=128)
            x = jnp.asarray(np.random.default_rng(0).standard_normal(
                dense.shape[1]).astype(np.float32))
            gf, us = spmv_gflops_measured(mat, x)
            fills[oname] = mat.fill_ratio()
            emit(f"table7/{name}/{oname}", us,
                 f"fill={mat.fill_ratio():.2f}%|gflops={gf:.4f}")
        # paper claim: descending minimizes fill
        emit(f"table7/{name}/descending_is_best_fill", 0.0,
             fills["descending"] <= min(fills.values()) + 1e-9)
        results[name] = fills
    return results


if __name__ == "__main__":
    run()
