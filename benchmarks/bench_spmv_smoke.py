"""Smoke benchmark: per-format SpMV µs/call on the small corpus → JSON.

Run by CI on every push (``.github/workflows/ci.yml``) so the perf
trajectory of the kernel pipeline is tracked from PR 1 onward:

    PYTHONPATH=src:. python benchmarks/bench_spmv_smoke.py --out BENCH_spmv.json

Per matrix it records the jnp-oracle µs/call for the reference formats, the
Pallas RgCSR kernel µs/call + grid steps at ``chunks_per_step`` 1 (the seed
schedule) and 4 (the coarsened schedule), and the autotuner's winning
config.  The summary aggregates the grid-step reduction and the tuned
speedup — the two acceptance figures of the coarsening PR.

Numbers are CPU interpret-mode on this container: per-grid-step overhead is
Python-level, so the *relative* effect of coarsening (fewer steps) is
visible even though absolute µs are not TPU figures (benchmarks/common.py
preamble).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
from typing import Dict

import jax
import numpy as np

from benchmarks.common import emit, spmv_gflops_measured, spmv_us_kernel
from repro.core import from_dense
from repro.core.suite import small_corpus
from repro.kernels import autotune

ORACLE_FORMATS = ("csr", "ellpack", "rgcsr")


def bench_one(spec, *, repeats: int, tune_max_n: int) -> Dict:
    a = spec.build()
    x = jax.numpy.asarray(
        np.random.default_rng(1).standard_normal(a.shape[1])
        .astype(np.float32))
    row: Dict = {"n": int(a.shape[0]), "nnz": int((a != 0).sum()),
                 "formats_us": {}, "kernel": {}}

    for fmt in ORACLE_FORMATS:
        mat = from_dense(a, fmt)
        _, us = spmv_gflops_measured(mat, x, repeats=repeats)
        row["formats_us"][fmt] = round(us, 2)
        emit(f"{spec.name}/{fmt}", us, "oracle")

    rg = from_dense(a, "rgcsr")
    us1, steps1 = spmv_us_kernel(rg, x, chunks_per_step=1, repeats=repeats)
    us4, steps4 = spmv_us_kernel(rg, x, chunks_per_step=4, repeats=repeats)
    row["kernel"] = {
        "us_cps1": round(us1, 2), "steps_cps1": steps1,
        "us_cps4": round(us4, 2), "steps_cps4": steps4,
        "step_reduction_cps4": round(steps1 / max(steps4, 1), 3),
    }
    emit(f"{spec.name}/rgcsr_kernel_cps1", us1, f"steps={steps1}")
    emit(f"{spec.name}/rgcsr_kernel_cps4", us4, f"steps={steps4}")

    if a.shape[0] <= tune_max_n:
        result = autotune.autotune_spmv(a, repeats=repeats)
        row["kernel"]["tuned"] = {
            "chunks_per_step": result.config.chunks_per_step,
            "group_size": result.config.group_size,
            "us": round(result.us_per_call, 2),
            "speedup_vs_baseline": round(result.speedup, 3),
            "from_memo": result.from_memo,
        }
        emit(f"{spec.name}/rgcsr_kernel_tuned", result.us_per_call,
             f"cps={result.config.chunks_per_step},"
             f"g={result.config.group_size}")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_spmv.json")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--tune-max-n", type=int, default=1024,
                    help="autotune only matrices up to this many rows")
    ap.add_argument("--max-n", type=int, default=0,
                    help="skip matrices larger than this (0 = no cap)")
    args = ap.parse_args(argv)

    matrices: Dict[str, Dict] = {}
    for spec in small_corpus():
        if args.max_n and spec.n > args.max_n:
            continue
        matrices[spec.name] = bench_one(spec, repeats=args.repeats,
                                        tune_max_n=args.tune_max_n)

    steps1 = sum(m["kernel"]["steps_cps1"] for m in matrices.values())
    steps4 = sum(m["kernel"]["steps_cps4"] for m in matrices.values())
    tuned = [m["kernel"]["tuned"] for m in matrices.values()
             if "tuned" in m["kernel"]]
    us1 = np.array([m["kernel"]["us_cps1"] for m in matrices.values()])
    us4 = np.array([m["kernel"]["us_cps4"] for m in matrices.values()])
    summary = {
        "total_grid_steps_cps1": steps1,
        "total_grid_steps_cps4": steps4,
        "overall_step_reduction_cps4": round(steps1 / max(steps4, 1), 3),
        "kernel_us_geomean_cps1": round(float(np.exp(np.log(us1).mean())), 2),
        "kernel_us_geomean_cps4": round(float(np.exp(np.log(us4).mean())), 2),
        "kernel_us_geomean_tuned": round(float(np.exp(np.mean(
            [np.log(t["us"]) for t in tuned]))), 2) if tuned else None,
        "n_autotuned": len(tuned),
        "n_tuned_coarsened": sum(t["chunks_per_step"] > 1 for t in tuned),
        "tuned_speedup_geomean": round(float(np.exp(np.mean(
            [np.log(max(t["speedup_vs_baseline"], 1e-9)) for t in tuned]
        ))), 3) if tuned else None,
    }
    doc = {
        "meta": {
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
            "python": platform.python_version(),
            "corpus": "small",
            "repeats": args.repeats,
        },
        "matrices": matrices,
        "summary": summary,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {args.out}: steps {steps1}→{steps4} "
          f"({summary['overall_step_reduction_cps4']}x), "
          f"{summary['n_tuned_coarsened']}/{summary['n_autotuned']} matrices "
          f"tuned to chunks_per_step>1")
    return 0


if __name__ == "__main__":
    sys.exit(main())
