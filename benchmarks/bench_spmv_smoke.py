"""Smoke benchmark: per-format SpMV µs/call on the small corpus → JSON.

Run by CI on every push (``.github/workflows/ci.yml``) so the perf
trajectory of the kernel pipeline is tracked from PR 1 onward:

    PYTHONPATH=src:. python benchmarks/bench_spmv_smoke.py --out BENCH_spmv.json

Per matrix it records the jnp-oracle µs/call for the reference formats, the
Pallas RgCSR kernel µs/call + grid steps at ``chunks_per_step`` 1 (the seed
schedule) and 4 (the coarsened schedule), the **adaptive** regrouped plan
(descending-length grouping + heuristic pathological-row spill, DESIGN.md
§5) with its ``padded_slot_fraction``, and the autotuner's winning config
from the joint ``(chunks, group, ordering, spill)`` search.  The summary
aggregates the grid-step reduction, the tuned speedup, and the
padding-reduction on the skewed (powerlaw/circuit) subset — the acceptance
figures of the coarsening (PR 1) and adaptive-grouping (PR 2) changes.

CI then gates on ``benchmarks/check_bench_regression.py``: the committed
``BENCH_spmv.json`` is the baseline, and a ≥10% tuned-geomean regression
fails the build.

Numbers are CPU interpret-mode on this container: per-grid-step overhead is
Python-level, so the *relative* effect of coarsening (fewer steps) is
visible even though absolute µs are not TPU figures (benchmarks/common.py
preamble).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
from typing import Dict

import jax
import numpy as np

from benchmarks.common import emit, spmv_gflops_measured, spmv_us_kernel
from repro.core import from_dense
from repro.core.suite import small_corpus
from repro.kernels import autotune
from repro.kernels import ops as kops

ORACLE_FORMATS = ("csr", "ellpack", "rgcsr")

# families with skewed row-length profiles — where adaptive grouping must win
SKEWED_FAMILIES = ("powerlaw", "circuit")


def _heuristic_spill(a: np.ndarray) -> int:
    """First matrix-derived spill threshold (0 when the profile is flat)."""
    cands = autotune.spill_threshold_candidates((a != 0).sum(axis=1))
    return cands[1] if len(cands) > 1 else 0


def bench_one(spec, *, repeats: int, tune_max_n: int) -> Dict:
    a = spec.build()
    x = jax.numpy.asarray(
        np.random.default_rng(1).standard_normal(a.shape[1])
        .astype(np.float32))
    row: Dict = {"n": int(a.shape[0]), "nnz": int((a != 0).sum()),
                 "family": spec.family, "formats_us": {}, "kernel": {}}

    for fmt in ORACLE_FORMATS:
        mat = from_dense(a, fmt)
        _, us = spmv_gflops_measured(mat, x, repeats=repeats)
        row["formats_us"][fmt] = round(us, 2)
        emit(f"{spec.name}/{fmt}", us, "oracle")

    rg = from_dense(a, "rgcsr")
    us1, steps1 = spmv_us_kernel(rg, x, chunks_per_step=1, repeats=repeats)
    us4, steps4 = spmv_us_kernel(rg, x, chunks_per_step=4, repeats=repeats)
    spill = _heuristic_spill(a)
    usa, steps_a = spmv_us_kernel(rg, x, chunks_per_step=1,
                                  ordering="adaptive",
                                  spill_threshold=spill, repeats=repeats)
    plan_block = kops.get_plan(rg, chunks_per_step=1)
    plan_adapt = kops.get_plan(rg, chunks_per_step=1, ordering="adaptive",
                               spill_threshold=spill)
    row["kernel"] = {
        "us_cps1": round(us1, 2), "steps_cps1": steps1,
        "us_cps4": round(us4, 2), "steps_cps4": steps4,
        "step_reduction_cps4": round(steps1 / max(steps4, 1), 3),
        "us_adaptive": round(usa, 2), "steps_adaptive": steps_a,
        "adaptive_spill_threshold": spill,
        "padded_slot_fraction_block":
            round(plan_block.padded_slot_fraction, 4),
        "padded_slot_fraction_adaptive":
            round(plan_adapt.padded_slot_fraction, 4),
        # artificial zeros stored (= wasted HBM bytes / itemsize+4); the
        # unsaturated twin of the fraction above — the fraction has hard
        # floors (128-lane groups when n < G, 8-slot sublane alignment)
        # that padding-count reduction does not.
        "padded_slots_block":
            plan_block.stored_elements - plan_block.nnz,
        "padded_slots_adaptive":
            plan_adapt.stored_elements - plan_adapt.nnz,
    }
    emit(f"{spec.name}/rgcsr_kernel_cps1", us1,
         f"steps={steps1},padfrac={plan_block.padded_slot_fraction:.3f}")
    emit(f"{spec.name}/rgcsr_kernel_cps4", us4, f"steps={steps4}")
    emit(f"{spec.name}/rgcsr_kernel_adaptive", usa,
         f"steps={steps_a},spill={spill},"
         f"padfrac={plan_adapt.padded_slot_fraction:.3f}")

    if a.shape[0] <= tune_max_n:
        result = autotune.autotune_spmv(a, repeats=repeats)
        win = result.config
        tuned_plan, _ = autotune.tuned_plan(a, repeats=repeats)
        row["kernel"]["tuned"] = {
            "chunks_per_step": win.chunks_per_step,
            "group_size": win.group_size,
            "ordering": win.ordering,
            "spill_threshold": win.spill_threshold,
            "us": round(result.us_per_call, 2),
            "speedup_vs_baseline": round(result.speedup, 3),
            "padded_slot_fraction":
                round(tuned_plan.padded_slot_fraction, 4),
            "from_memo": result.from_memo,
            "timing_source": result.timing_source,
        }
        emit(f"{spec.name}/rgcsr_kernel_tuned", result.us_per_call,
             f"cps={win.chunks_per_step},g={win.group_size},"
             f"ord={win.ordering},spill={win.spill_threshold}")
    return row


def _geomean(vals) -> float:
    vals = np.asarray([max(float(v), 1e-9) for v in vals])
    return float(np.exp(np.log(vals).mean())) if vals.size else float("nan")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_spmv.json")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--tune-max-n", type=int, default=1024,
                    help="autotune only matrices up to this many rows")
    ap.add_argument("--max-n", type=int, default=0,
                    help="skip matrices larger than this (0 = no cap)")
    args = ap.parse_args(argv)

    # one clock for the whole run: the CI gate normalizes tuned µs by the
    # in-run wallclock cps=1 timing, so the tuner must measure with the
    # same clock — and interpret-mode CPU "device" events sum parallel
    # op durations, which is not comparable to wall time.  The forced
    # source is recorded in meta.timing_source; on real hardware, drop
    # this override to rank by true device time (DESIGN.md §13.4).
    autotune.set_timing_source("wallclock")

    matrices: Dict[str, Dict] = {}
    for spec in small_corpus():
        if args.max_n and spec.n > args.max_n:
            continue
        matrices[spec.name] = bench_one(spec, repeats=args.repeats,
                                        tune_max_n=args.tune_max_n)

    kernels = [m["kernel"] for m in matrices.values()]
    steps1 = sum(k["steps_cps1"] for k in kernels)
    steps4 = sum(k["steps_cps4"] for k in kernels)
    tuned = [k["tuned"] for k in kernels if "tuned" in k]
    skewed = [m["kernel"] for m in matrices.values()
              if m["family"] in SKEWED_FAMILIES]
    skewed_tuned = [m["kernel"]["tuned"] for m in matrices.values()
                    if m["family"] in SKEWED_FAMILIES
                    and "tuned" in m["kernel"]]
    summary = {
        "total_grid_steps_cps1": steps1,
        "total_grid_steps_cps4": steps4,
        "overall_step_reduction_cps4": round(steps1 / max(steps4, 1), 3),
        "kernel_us_geomean_cps1": round(
            _geomean(k["us_cps1"] for k in kernels), 2),
        "kernel_us_geomean_cps4": round(
            _geomean(k["us_cps4"] for k in kernels), 2),
        "kernel_us_geomean_adaptive": round(
            _geomean(k["us_adaptive"] for k in kernels), 2),
        "kernel_us_geomean_tuned": round(
            _geomean(t["us"] for t in tuned), 2) if tuned else None,
        "n_autotuned": len(tuned),
        "n_tuned_coarsened": sum(t["chunks_per_step"] > 1 for t in tuned),
        "n_tuned_adaptive": sum(t["ordering"] == "adaptive" for t in tuned),
        "tuned_speedup_geomean": round(_geomean(
            t["speedup_vs_baseline"] for t in tuned), 3) if tuned else None,
        # the adaptive-grouping acceptance figures (skewed subset)
        "skewed_padfrac_block_mean": round(float(np.mean(
            [k["padded_slot_fraction_block"] for k in skewed])), 4)
            if skewed else None,
        "skewed_padfrac_adaptive_mean": round(float(np.mean(
            [k["padded_slot_fraction_adaptive"] for k in skewed])), 4)
            if skewed else None,
        "skewed_padded_slots_reduction_geomean": round(_geomean(
            k["padded_slots_block"] / max(k["padded_slots_adaptive"], 1)
            for k in skewed), 2) if skewed else None,
        "skewed_us_geomean_cps1": round(
            _geomean(k["us_cps1"] for k in skewed), 2) if skewed else None,
        "skewed_us_geomean_adaptive": round(
            _geomean(k["us_adaptive"] for k in skewed), 2)
            if skewed else None,
        "skewed_us_geomean_tuned": round(
            _geomean(t["us"] for t in skewed_tuned), 2)
            if skewed_tuned else None,
    }
    doc = {
        "meta": {
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
            "python": platform.python_version(),
            "corpus": "small",
            "repeats": args.repeats,
            # how candidate kernels were timed: "profiler" = device-event
            # durations from jax.profiler traces, "wallclock" = host
            # time.perf_counter around block_until_ready (DESIGN.md §13.4)
            "timing_source": autotune.timing_source(),
        },
        "matrices": matrices,
        "summary": summary,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"# wrote {args.out}: steps {steps1}→{steps4} "
          f"({summary['overall_step_reduction_cps4']}x), "
          f"{summary['n_tuned_coarsened']}/{summary['n_autotuned']} tuned to "
          f"cps>1, {summary['n_tuned_adaptive']}/{summary['n_autotuned']} "
          f"tuned to adaptive; skewed padfrac "
          f"{summary['skewed_padfrac_block_mean']}→"
          f"{summary['skewed_padfrac_adaptive_mean']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
