"""CI perf gate: diff fresh benchmark JSONs against the committed baselines.

    python benchmarks/check_bench_regression.py \
        --baseline BENCH_baseline.json --new BENCH_spmv.json \
        --max-geomean-regression 0.10

    python benchmarks/check_bench_regression.py \
        --sharded-baseline BENCH_sharded_baseline.json \
        --sharded-new BENCH_spmv_sharded.json

Interpret-mode µs are machine-speed-dependent, and the committed baseline
was produced on a different machine than the CI runner — so the gate
compares a **within-run normalized** metric: each matrix's tuned kernel µs
divided by the *same run's* cps=1 block-schedule µs.  Uniform machine speed
cancels out of that ratio; what remains is how much the tuned schedule
beats the fixed reference schedule, which is exactly what a code regression
in the plan/tuner/kernel pipeline degrades.  Matrices without a tuned
entry on both sides are skipped (adding/dropping a tuner entry for one
matrix cannot flip the gate).

The gate fails when the geomean of (normalized_new / normalized_baseline)
exceeds ``1 + threshold`` (default: 10%).  Per-matrix ratios print
worst-first so a red run names its regressing matrices; the gate is on the
geomean, not the max, because per-matrix interpret-mode jitter is large.

The **sharded** gate applies the same normalization to
``BENCH_spmv_sharded.json`` — each split/tuned variant's µs over the same
run's ``block_replicated`` µs, compared per (matrix, variant) — and
additionally gates the §12 sparse-collective **exchange volume**: the new
run must (a) satisfy the structural bound ``exchange_recv_cols ==
remote_cols`` per shard, and (b) never move more exchange bytes per matrix
than the baseline did (falling back to the baseline's remote-column counts
× 4 B when it predates the exchange metric).  Exchange figures are
deterministic plan properties, so they gate exactly, machine-independent.

The **serve** gate (``--serve-baseline/--serve-new``, BENCH_serve.json)
bounds the paged KV-cache metrics, which are deterministic allocation
properties of the fixed request mixes (greedy, no EOS): per mix, the page
high-water mark and ``pages_per_token`` may **never grow**, and paged peak
residency must stay ≤ the dense ``(n_slots, S_max)`` equivalent (strictly
below it on the mixed-length mix).  The overload mix's preemption counters
(``preemptions``, ``recompute_tokens``, ``rejected``) are likewise
deterministic allocator properties and may never grow — a regression in
the §6.4 recompute-preemption path (more evictions, more recomputed
tokens, spurious rejections) fails exactly.  ``dispatches_per_token``
(fused decode launches per generated token, DESIGN.md §7.1) is a
deterministic chunk-cadence property and gates never-grow on every mix —
the decode loop cannot silently fall back toward one launch per token.
Serve wall-clock timings are recorded but never gated — they are the
only machine-speed-dependent fields.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _normalized_us(kernel: dict):
    """tuned µs / same-run cps=1 µs, or None when not comparable."""
    tuned = kernel.get("tuned")
    base = float(kernel.get("us_cps1", 0))
    if tuned is None or base <= 0:
        return None
    return float(tuned["us"]) / base


def compare(baseline: dict, new: dict):
    """Returns (ratios {name: normalized_new/normalized_old}, geomean)."""
    ratios = {}
    for name, row in new.get("matrices", {}).items():
        base_row = baseline.get("matrices", {}).get(name)
        if base_row is None:
            continue
        old = _normalized_us(base_row["kernel"])
        cur = _normalized_us(row["kernel"])
        if old and cur:
            ratios[name] = cur / old
    if not ratios:
        return ratios, 1.0
    geomean = float(np.exp(np.mean([np.log(r) for r in ratios.values()])))
    return ratios, geomean


def _sharded_normalized(row: dict, label: str):
    """variant µs / same-run block_replicated µs, or None."""
    sh = row.get("sharded", {})
    base = float(sh.get("block_replicated", {}).get("us", 0))
    entry = sh.get(label)
    if entry is None or base <= 0:
        return None
    return float(entry["us"]) / base


def _exchange_bytes_total(row: dict):
    """Total exchange bytes a matrix's split path moves, from the newest
    metric available: exchange_bytes_per_shard, else remote_cols × 4 B
    (pre-§12 baselines recorded the plan-time remote sets only — the
    sparse collective moves exactly those entries, so they are the bound)."""
    entry = row.get("sharded", {}).get("block_split")
    if entry is None:
        return None
    if "exchange_bytes_per_shard" in entry:
        return sum(entry["exchange_bytes_per_shard"])
    if "remote_cols_per_shard" in entry:
        return sum(entry["remote_cols_per_shard"]) * 4
    return None


def _exchange_padded_cols(row: dict):
    """The per-device collective buffer width D·e_max — the true wire
    footprint of the all_to_all.  A remap that concentrates one (src, dst)
    edge raises e_max (and the real traffic) without changing the unpadded
    entry counts, so both are gated."""
    entry = row.get("sharded", {}).get("block_split")
    if entry is None:
        return None
    return entry.get("exchange_padded_recv_cols")


def compare_sharded(baseline: dict, new: dict):
    """Returns (us_ratios {(matrix, label): ...}, geomean, failures [str])."""
    ratios = {}
    failures = []
    for name, row in new.get("matrices", {}).items():
        # structural bound on the new run: the sparse collective receives
        # exactly the plan-time remote sets — never more
        for label, entry in row.get("sharded", {}).items():
            recv = entry.get("exchange_recv_cols_per_shard")
            remote = entry.get("remote_cols_per_shard")
            if recv is not None and remote is not None and recv != remote:
                failures.append(
                    f"{name}/{label}: exchange recv cols {recv} != "
                    f"plan remote cols {remote}")
        base_row = baseline.get("matrices", {}).get(name)
        if base_row is None:
            continue
        # exchange volume must never grow vs the committed baseline —
        # neither the real entry counts nor the padded collective width
        old_x = _exchange_bytes_total(base_row)
        new_x = _exchange_bytes_total(row)
        if old_x is not None and new_x is not None and new_x > old_x:
            failures.append(f"{name}: exchange bytes grew {old_x} -> "
                            f"{new_x}")
        old_p = _exchange_padded_cols(base_row)
        new_p = _exchange_padded_cols(row)
        if old_p is not None and new_p is not None and new_p > old_p:
            failures.append(f"{name}: padded collective width grew "
                            f"{old_p} -> {new_p} recv cols")
        for label in row.get("sharded", {}):
            if label == "block_replicated":
                continue
            old = _sharded_normalized(base_row, label)
            cur = _sharded_normalized(row, label)
            if old and cur:
                ratios[(name, label)] = cur / old
    if ratios:
        geomean = float(np.exp(np.mean(
            [np.log(r) for r in ratios.values()])))
    else:
        geomean = 1.0
    return ratios, geomean, failures


def compare_serve(baseline: dict, new: dict):
    """Exact never-grow bounds on the deterministic paging metrics.

    Returns a list of failure strings (empty = pass).  Mixes present on
    only one side are skipped (adding a mix cannot flip the gate)."""
    failures = []
    for name, row in new.get("mixes", {}).items():
        paged = row.get("paged", {})
        # structural bound within the new run: residency never above dense
        peak = paged.get("paged_peak_tokens")
        dense_eq = paged.get("dense_equiv_tokens")
        if peak is not None and dense_eq is not None and peak > dense_eq:
            failures.append(f"{name}: paged peak {peak} tokens exceeds "
                            f"dense equivalent {dense_eq}")
        if name == "mixed_length" and peak is not None \
                and dense_eq is not None and peak >= dense_eq:
            failures.append(f"{name}: no residency win over dense "
                            f"({peak} >= {dense_eq})")
        base = baseline.get("mixes", {}).get(name, {}).get("paged")
        if base is None:
            continue
        # page metrics everywhere; overload adds the §6.4 preemption
        # counters and router_kill the §7 fault-tolerance counters; all
        # mixes gate the §7.1 fused-loop dispatches_per_token so the
        # decode path can't regress toward one launch per token (both
        # sides must carry a key for it to gate, so older baselines
        # without a mix or metric cannot flip this)
        for key in ("page_high_water", "pages_per_token",
                    "preemptions", "recompute_tokens", "rejected",
                    "migrations", "retries_exhausted", "shed",
                    "dispatches_per_token",
                    # §7.6 crash_restore recovery-cost budget: tokens
                    # re-prefilled after a restore and pool capacity
                    # retired by the integrity checker
                    "restore_recompute_tokens", "pages_quarantined"):
            old_v, new_v = base.get(key), paged.get(key)
            if old_v is not None and new_v is not None and new_v > old_v:
                failures.append(
                    f"{name}: {key} grew {old_v} -> {new_v}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline")
    ap.add_argument("--new")
    ap.add_argument("--sharded-baseline")
    ap.add_argument("--sharded-new")
    ap.add_argument("--serve-baseline")
    ap.add_argument("--serve-new")
    ap.add_argument("--max-geomean-regression", type=float, default=0.10,
                    help="fail when geomean(new/baseline) > 1 + this")
    args = ap.parse_args(argv)
    if bool(args.baseline) != bool(args.new):
        ap.error("--baseline and --new must be given together")
    if bool(args.sharded_baseline) != bool(args.sharded_new):
        ap.error("--sharded-baseline and --sharded-new must be given "
                 "together")
    if bool(args.serve_baseline) != bool(args.serve_new):
        ap.error("--serve-baseline and --serve-new must be given together")
    if not args.baseline and not args.sharded_baseline \
            and not args.serve_baseline:
        ap.error("nothing to gate: pass --baseline/--new, "
                 "--sharded-baseline/--sharded-new and/or "
                 "--serve-baseline/--serve-new")
    limit = 1.0 + args.max_geomean_regression
    rc = 0

    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
        ratios, geomean = compare(baseline, new)
        if not ratios:
            print("# no comparable matrices between baseline and new run; "
                  "nothing to gate")
        else:
            for name, r in sorted(ratios.items(), key=lambda kv: -kv[1]):
                flag = " <-- regressed" if r > limit else ""
                print(f"{name},{r:.3f}{flag}")
            print(f"# geomean of normalized tuned-us ratios = {geomean:.3f} "
                  f"(limit {limit:.3f}, {len(ratios)} matrices)")
            if geomean > limit:
                print(f"# FAIL: tuned SpMV (normalized to the in-run cps=1 "
                      f"schedule) regressed {100 * (geomean - 1):.1f}% > "
                      f"{100 * args.max_geomean_regression:.0f}%",
                      file=sys.stderr)
                rc = 1

    if args.sharded_baseline:
        with open(args.sharded_baseline) as f:
            sh_base = json.load(f)
        with open(args.sharded_new) as f:
            sh_new = json.load(f)
        ratios, geomean, failures = compare_sharded(sh_base, sh_new)
        for (name, label), r in sorted(ratios.items(), key=lambda kv: -kv[1]):
            flag = " <-- regressed" if r > limit else ""
            print(f"sharded:{name}/{label},{r:.3f}{flag}")
        print(f"# sharded geomean of normalized us ratios = {geomean:.3f} "
              f"(limit {limit:.3f}, {len(ratios)} matrix/variant pairs)")
        for msg in failures:
            print(f"# FAIL(sharded exchange): {msg}", file=sys.stderr)
        if failures:
            rc = 1
        if ratios and geomean > limit:
            print(f"# FAIL: sharded SpMV (normalized to the in-run "
                  f"block_replicated schedule) regressed "
                  f"{100 * (geomean - 1):.1f}% > "
                  f"{100 * args.max_geomean_regression:.0f}%",
                  file=sys.stderr)
            rc = 1

    if args.serve_baseline:
        with open(args.serve_baseline) as f:
            sv_base = json.load(f)
        with open(args.serve_new) as f:
            sv_new = json.load(f)
        failures = compare_serve(sv_base, sv_new)
        for name, row in sorted(sv_new.get("mixes", {}).items()):
            paged = row.get("paged", {})
            print(f"serve:{name},hwm={paged.get('page_high_water')},"
                  f"pages_per_token={paged.get('pages_per_token')},"
                  f"dispatches_per_token="
                  f"{paged.get('dispatches_per_token')}")
        for msg in failures:
            print(f"# FAIL(serve paging): {msg}", file=sys.stderr)
        if failures:
            rc = 1

    if rc == 0:
        print("# PASS")
    else:
        print("# FAIL", file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
