"""CI perf gate: diff a fresh BENCH_spmv.json against the committed baseline.

    python benchmarks/check_bench_regression.py \
        --baseline BENCH_baseline.json --new BENCH_spmv.json \
        --max-geomean-regression 0.10

Interpret-mode µs are machine-speed-dependent, and the committed baseline
was produced on a different machine than the CI runner — so the gate
compares a **within-run normalized** metric: each matrix's tuned kernel µs
divided by the *same run's* cps=1 block-schedule µs.  Uniform machine speed
cancels out of that ratio; what remains is how much the tuned schedule
beats the fixed reference schedule, which is exactly what a code regression
in the plan/tuner/kernel pipeline degrades.  Matrices without a tuned
entry on both sides are skipped (adding/dropping a tuner entry for one
matrix cannot flip the gate).

The gate fails when the geomean of (normalized_new / normalized_baseline)
exceeds ``1 + threshold`` (default: 10%).  Per-matrix ratios print
worst-first so a red run names its regressing matrices; the gate is on the
geomean, not the max, because per-matrix interpret-mode jitter is large.
"""
from __future__ import annotations

import argparse
import json
import sys

import numpy as np


def _normalized_us(kernel: dict):
    """tuned µs / same-run cps=1 µs, or None when not comparable."""
    tuned = kernel.get("tuned")
    base = float(kernel.get("us_cps1", 0))
    if tuned is None or base <= 0:
        return None
    return float(tuned["us"]) / base


def compare(baseline: dict, new: dict):
    """Returns (ratios {name: normalized_new/normalized_old}, geomean)."""
    ratios = {}
    for name, row in new.get("matrices", {}).items():
        base_row = baseline.get("matrices", {}).get(name)
        if base_row is None:
            continue
        old = _normalized_us(base_row["kernel"])
        cur = _normalized_us(row["kernel"])
        if old and cur:
            ratios[name] = cur / old
    if not ratios:
        return ratios, 1.0
    geomean = float(np.exp(np.mean([np.log(r) for r in ratios.values()])))
    return ratios, geomean


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--new", required=True)
    ap.add_argument("--max-geomean-regression", type=float, default=0.10,
                    help="fail when geomean(new/baseline) > 1 + this")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    ratios, geomean = compare(baseline, new)
    if not ratios:
        print("# no comparable matrices between baseline and new run; "
              "nothing to gate")
        return 0

    for name, r in sorted(ratios.items(), key=lambda kv: -kv[1]):
        flag = " <-- regressed" if r > 1.0 + args.max_geomean_regression \
            else ""
        print(f"{name},{r:.3f}{flag}")
    limit = 1.0 + args.max_geomean_regression
    print(f"# geomean of normalized tuned-us ratios = {geomean:.3f} "
          f"(limit {limit:.3f}, {len(ratios)} matrices)")
    if geomean > limit:
        print(f"# FAIL: tuned SpMV (normalized to the in-run cps=1 "
              f"schedule) regressed {100 * (geomean - 1):.1f}% > "
              f"{100 * args.max_geomean_regression:.0f}%", file=sys.stderr)
        return 1
    print("# PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
