"""Serving benchmark: mixed-length prompt mixes through the paged engine.

    PYTHONPATH=src:. python benchmarks/bench_serve.py --out BENCH_serve.json

Per request mix it serves the queue through the **paged** engine and the
**dense** engine (same smoke model, greedy so token streams are identical)
and records per-request latency/queue/prefill timings plus the paging
counters from ``Engine.paging_stats``: page high-water mark, fragmentation
at peak, admission deferrals, and the derived

* ``paged_peak_tokens``  — high-water pages × page_size, the residency a
  right-sized pool needs (the acceptance metric: ≤ dense everywhere,
  strictly lower on mixed-length mixes), and
* ``pages_per_token``    — paged_peak_tokens / peak live tokens ≥ 1.0, the
  internal-fragmentation overhead of page granularity.

The page metrics are **deterministic plan properties** of the request mix
(greedy sampling, ``eos_id=-1`` so generation lengths are fixed): the CI
gate (``check_bench_regression.py --serve-baseline/--serve-new``) bounds
them exactly — pages-per-token and the high-water mark may never grow —
while wall-clock timings are informational only, so the gate cannot flake
on a loaded runner (the PR 3 determinism lesson).

Dispatch amortization (DESIGN.md §7.1): every mix also records
``decode_dispatches`` (fused on-device chunk launches),
``tokens_per_dispatch`` (decode steps amortized per launch), and
``dispatches_per_token`` — the last is CI-gated never-grow, so the fused
decode loop can't silently regress back toward one launch per token.

The **overload** mix (DESIGN.md §6.4) drives a pool sized below the
queue's aggregate worst case through the default prompt-pages admission
policy, with one oversized request mixed in: every healthy request must
complete via recompute preemption (token streams still deterministic) and
the oversized one must be rejected per-request.  Its ``preemptions``,
``recompute_tokens``, and ``rejected`` counts are deterministic allocator
properties and CI-gated never-grow, like the page metrics.

The **router_kill** mix (DESIGN.md §7) runs 3 engine replicas behind the
fault-tolerant Router, kills one mid-decode through the site-qualified
injector, and bounds the router queue so the submission tail is shed: the
surviving replicas absorb the dead one's in-flight requests (recompute
migration — streams asserted token-identical to the single-engine
oracle), and ``migrations`` / ``retries_exhausted`` / ``shed`` are
deterministic scheduler properties, CI-gated never-grow.

The **crash_restore** mix (DESIGN.md §7.6) snapshots a kv_integrity
session mid-decode, restores it into a fresh engine (simulated process
death), corrupts one live KV page through the injector during the drained
tail, and asserts every stream token-identical to the oracle.  Its
``restore_recompute_tokens`` and ``pages_quarantined`` counters are the
deterministic recovery-cost budget and CI-gated never-grow;
``snapshot_bytes`` is informational.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import Dict

import numpy as np

# request mixes: (name, prompt lengths cycled over `requests`, max_new)
MIXES = (
    ("uniform_short", (16,), 8),
    ("uniform_long", (48,), 8),
    ("mixed_length", (8, 48, 16, 64, 24, 8), 8),
    ("mixed_budget", (12, 12, 12), 16),
)
MAX_SEQ = 96
N_SLOTS = 4
PAGE_SIZE = 8
N_REQUESTS = 12


def _requests(cfg, lengths, max_new, n):
    from repro.serve import Request
    rng = np.random.default_rng(0)
    return [Request(tokens=rng.integers(0, cfg.vocab,
                                        (lengths[i % len(lengths)],)
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _percentile_metrics(st: Dict) -> Dict:
    """p50/p95/p99 for queue_s / prefill_s / latency_s, read from the
    session's metrics histograms (``latency_percentiles`` in the stats
    view, DESIGN.md §13.1).  Wall-clock dependent → informational only,
    never CI-gated."""
    row = {}
    for hname, qs in (st.get("latency_percentiles") or {}).items():
        for q, val in qs.items():
            row[f"{hname}_{q}"] = val
    return row


def _dispatch_metrics(st: Dict, total_tokens: int) -> Dict:
    """Fused-loop amortization (deterministic, ``dispatches_per_token``
    CI-gated never-grow): decode steps per on-device launch, and
    launches per generated token (prefill-sampled tokens included — the
    stepwise engine's baseline here was ~1 dispatch per decode token)."""
    d = st["decode_dispatches"]
    return {
        "decode_dispatches": d,
        "tokens_per_dispatch": round(st["decode_steps"] / max(d, 1), 2),
        "dispatches_per_token": round(d / max(total_tokens, 1), 4),
    }


def bench_mix(eng, cfg, name, lengths, max_new) -> Dict:
    reqs = _requests(cfg, lengths, max_new, N_REQUESTS)
    t0 = time.time()
    eng.serve(reqs)
    wall_s = time.time() - t0
    assert all(r.done for r in reqs), f"{name}: unfinished requests"
    lat = np.array([r.latency_s for r in reqs])
    st = dict(eng.paging_stats)
    total_tokens = int(sum(len(r.out) for r in reqs))
    row = {
        "lengths": list(lengths),
        "max_new_tokens": max_new,
        "n_requests": N_REQUESTS,
        "total_tokens": total_tokens,
        # informational (machine-speed dependent; NOT gated)
        "wall_s": round(wall_s, 4),
        "tok_per_s": round(total_tokens / wall_s, 2),
        "latency_s_mean": round(float(lat.mean()), 4),
        "latency_s_max": round(float(lat.max()), 4),
        "queue_s_max": round(max(r.queue_s for r in reqs), 4),
        "decode_steps": st["decode_steps"],
    }
    row.update(_percentile_metrics(st))
    row.update(_dispatch_metrics(st, total_tokens))
    # layout-agnostic since the overload PR: the dense layout used to
    # report 0 here, breaking the paged-vs-dense residency comparison
    row["peak_live_tokens"] = st["peak_live_tokens"]
    if st["kv_layout"] == "paged":
        peak_live = max(st["peak_live_tokens"], 1)
        row.update({
            # deterministic plan properties (gated exactly in CI)
            "page_size": st["page_size"],
            "page_high_water": st["page_high_water"],
            "paged_peak_tokens": st["paged_peak_tokens"],
            "dense_equiv_tokens": st["dense_equiv_tokens"],
            "pages_per_token": round(st["paged_peak_tokens"] / peak_live, 4),
            "frag_at_high_water": round(st["frag_at_high_water"], 4),
            "admission_deferrals": st["admission_deferrals"],
        })
    return row


# overload mix geometry (DESIGN.md §6.4): 3 slots but only 4 usable pages
# of 8 tokens — each healthy request (8-token prompt, 5 new tokens) worst-
# cases to 2 pages, so three concurrent requests exceed the pool and the
# prompt-pages policy must preempt; the oversized request worst-cases to
# 5 pages > the whole pool and must be rejected per-request.
OVERLOAD = dict(n_slots=3, page_size=8, n_pages=5, n_requests=10,
                prompt_len=8, max_new=5, oversized_len=16, oversized_new=20)


def bench_overload(cfg) -> Dict:
    from repro.serve import Engine, Request, ServeConfig
    ov = OVERLOAD
    eng = Engine(cfg, ServeConfig(
        max_seq=MAX_SEQ, n_slots=ov["n_slots"], page_size=ov["page_size"],
        n_pages=ov["n_pages"], temperature=0.0, eos_id=-1,
        admission_policy="prompt"))
    rng = np.random.default_rng(1)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (ov["prompt_len"],)
                                        ).astype(np.int32),
                    max_new_tokens=ov["max_new"])
            for _ in range(ov["n_requests"])]
    # oversized request right behind the head: rejected at admission while
    # everyone else keeps serving
    reqs.insert(1, Request(tokens=rng.integers(
        0, cfg.vocab, (ov["oversized_len"],)).astype(np.int32),
        max_new_tokens=ov["oversized_new"]))
    t0 = time.time()
    eng.serve(reqs)
    wall_s = time.time() - t0
    assert all(r.done for r in reqs), "overload: unfinished requests"
    healthy = [r for r in reqs if r.status != "rejected"]
    assert len(healthy) == ov["n_requests"]
    assert all(r.ok_like and len(r.out) == ov["max_new"] for r in healthy), \
        "overload: healthy request did not complete"
    st = dict(eng.paging_stats)
    assert st["preemptions"] > 0, "overload mix exercised no preemption"
    assert st["rejected"] == 1
    peak_live = max(st["peak_live_tokens"], 1)
    return {
        **{k: ov[k] for k in ("n_slots", "page_size", "n_pages",
                              "prompt_len", "max_new")},
        "n_requests": len(reqs),
        "total_tokens": int(sum(len(r.out) for r in reqs)),
        "wall_s": round(wall_s, 4),                     # informational
        "decode_steps": st["decode_steps"],
        **_percentile_metrics(st),                      # informational
        **_dispatch_metrics(st, int(sum(len(r.out) for r in reqs))),
        # deterministic overload counters (gated never-grow in CI)
        "preemptions": st["preemptions"],
        "recompute_tokens": st["recompute_tokens"],
        "rejected": st["rejected"],
        "failed": st["failed"],
        "timed_out": st["timed_out"],
        "completed": st["completed"],
        "pages_evicted": st["pages_evicted"],
        "admission_deferrals": st["admission_deferrals"],
        # page metrics, same shape as the standard mixes
        "page_high_water": st["page_high_water"],
        "paged_peak_tokens": st["paged_peak_tokens"],
        "dense_equiv_tokens": st["dense_equiv_tokens"],
        "peak_live_tokens": st["peak_live_tokens"],
        "pages_per_token": round(st["paged_peak_tokens"] / peak_live, 4),
    }


# router mix geometry (DESIGN.md §7): 3 replicas, one killed on its 3rd
# decode step (site-qualified injector), bounded router queue so the
# submission tail is shed.  Engine clocks run on a fake timer advanced per
# decode step, so fault timing, migrations, restart scheduling, and shed
# counts are deterministic plan properties of the mix — CI-gateable —
# while wall_s stays informational.
ROUTER = dict(n_replicas=3, n_slots=2, page_size=8, queue_limit=6,
              n_requests=10, prompt_len=8, max_new=6,
              kill_replica=1, kill_at_step=2)


def bench_router(cfg) -> Dict:
    from repro.serve import Engine, Request, Router, RouterConfig, \
        ServeConfig
    from repro.train.fault import FaultConfig, FaultInjector
    rv = ROUTER

    class _Clock:
        t = 0.0

        def __call__(self):
            return self.t

    clock = _Clock()
    scfg = ServeConfig(max_seq=MAX_SEQ, n_slots=rv["n_slots"],
                       page_size=rv["page_size"], temperature=0.0,
                       eos_id=-1)
    fault_cfg = FaultConfig(max_restarts=3, backoff_s=0.5)
    first = Engine(cfg, scfg, fault_cfg=fault_cfg)
    engines = [first] + [Engine(cfg, scfg, params=first.params,
                                fault_cfg=fault_cfg)
                         for _ in range(rv["n_replicas"] - 1)]
    engines[rv["kill_replica"]].fault_injector = FaultInjector(
        fail_at_steps=(("replica", rv["kill_at_step"]),))
    for e in engines:
        e.clock = clock
        orig = e._decode
        orig_fused = e._fused_decode

        def tick(*a, _orig=orig):
            clock.t += 1.0
            return _orig(*a)

        def tick_fused(*a, _orig=orig_fused):
            # one fused chunk = up to decode_chunk steps: advance the
            # fake clock by the steps that actually ran, keeping fault
            # timing and restart scheduling step-deterministic
            out = _orig(*a)
            clock.t += float(int(out[1]))
            return out

        e._decode = tick
        e._fused_decode = tick_fused
    router = Router(engines, cfg=RouterConfig(
        n_replicas=rv["n_replicas"], queue_limit=rv["queue_limit"]),
        fault_cfg=fault_cfg, clock=clock,
        sleep=lambda s: setattr(clock, "t", clock.t + s))
    rng = np.random.default_rng(2)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (rv["prompt_len"],)
                                        ).astype(np.int32),
                    max_new_tokens=rv["max_new"])
            for _ in range(rv["n_requests"])]
    t0 = time.time()
    router.serve(reqs)
    wall_s = time.time() - t0
    assert all(r.done for r in reqs), "router: unfinished requests"
    shed = [r for r in reqs if r.status == "shed"]
    served = [r for r in reqs if r.status != "shed"]
    assert len(shed) == rv["n_requests"] - rv["queue_limit"], \
        "router: backpressure bound did not hold"
    assert all(r.ok_like for r in served), \
        "router: a request failed instead of migrating"
    # THE acceptance assert: every stream — including every migrated one —
    # is token-identical to the single-engine greedy oracle
    for r in served:
        oracle = list(engines[0].generate(
            r.tokens[None, :], max_new_tokens=r.max_new_tokens)[0])
        assert r.out == oracle, "router: migrated stream drifted from oracle"
    st = router.stats()
    assert st["replica_faults"] == 1 and st["migrations"] > 0
    assert st["failed"] == 0 and st["retries_exhausted"] == 0
    return {
        **{k: rv[k] for k in ("n_replicas", "n_slots", "page_size",
                              "queue_limit", "prompt_len", "max_new")},
        "n_requests": rv["n_requests"],
        "total_tokens": int(sum(len(r.out) for r in served)),
        "wall_s": round(wall_s, 4),                     # informational
        "decode_steps": st["decode_steps"],
        **_percentile_metrics(st),                      # informational
        **_dispatch_metrics(st, int(sum(len(r.out) for r in served))),
        # deterministic fault-tolerance counters (gated never-grow in CI)
        "migrations": st["migrations"],
        "retries_exhausted": st["retries_exhausted"],
        "shed": st["shed"],
        "failed": st["failed"],
        "replica_faults": st["replica_faults"],
        "replica_restarts": st["replica_restarts"],
        "completed": st["completed"],
        "preemptions": st["preemptions"],
        "recompute_tokens": st["recompute_tokens"],
        "rejected": st["rejected"],
        "timed_out": st["timed_out"],
        # page metrics: fleet max + the per-replica spread
        "page_high_water": st["page_high_water"],
        "page_high_water_per_replica": st["page_high_water_per_replica"],
        "peak_live_tokens": st["peak_live_tokens"],
    }


# crash_restore mix geometry (DESIGN.md §7.6): serve with kv_integrity on,
# snapshot the session mid-decode, "kill" the process (fresh engine, shared
# params), restore from the snapshot, arm one silent page corruption in the
# drained tail, and drain.  Every stream — pre-crash prefix plus post-
# restore tail, including the corruption victim's recompute — must be
# token-identical to the single-engine greedy oracle.  The recovery-cost
# counters (``restore_recompute_tokens``: tokens re-prefilled to rebuild
# the dead process's KV; ``pages_quarantined``: pool capacity retired by
# the integrity checker) are deterministic plan properties and CI-gated
# never-grow; ``snapshot_bytes`` is informational (float age strings vary).
CRASH = dict(n_slots=2, page_size=8, n_requests=6, prompt_len=12,
             max_new=20, snapshot_after_steps=6, corrupt_page=1)


def bench_crash_restore(cfg) -> Dict:
    from repro.serve import Engine, Request, ServeConfig
    from repro.train.fault import FaultInjector
    cv = CRASH
    scfg = ServeConfig(max_seq=MAX_SEQ, n_slots=cv["n_slots"],
                       page_size=cv["page_size"], temperature=0.0,
                       eos_id=-1, kv_integrity=True)
    eng = Engine(cfg, scfg)
    rng = np.random.default_rng(3)
    reqs = [Request(tokens=rng.integers(0, cfg.vocab, (cv["prompt_len"],)
                                        ).astype(np.int32),
                    max_new_tokens=cv["max_new"])
            for _ in range(cv["n_requests"])]
    oracle = {r.tokens.tobytes(): list(eng.generate(
        r.tokens[None, :], max_new_tokens=cv["max_new"])[0]) for r in reqs}

    t0 = time.time()
    sess = eng.start_session(list(reqs))
    sess.step(cv["snapshot_after_steps"])
    snap = sess.snapshot()
    snapshot_bytes = len(json.dumps(snap).encode())
    # process death: a fresh engine (weights survive, host state does not)
    # restores the snapshot and drains, with one silent page corruption
    # armed so the integrity checker earns its keep mid-recovery
    eng2 = Engine(cfg, scfg, params=eng.params)
    inj = FaultInjector(fail_at_steps=(("page", cv["corrupt_page"]),))
    sess2, restored = eng2.restore_session(snap, fault_injector=inj)
    sess2.drain()
    wall_s = time.time() - t0

    done = [r for r in reqs if r.done] + restored
    assert len(done) == cv["n_requests"], "crash_restore: lost a request"
    assert all(r.done and r.ok_like for r in done), \
        "crash_restore: a request failed across the crash"
    # THE acceptance assert: every stream survives the kill + corruption
    # token-identical to the oracle
    for r in done:
        assert r.out == oracle[r.tokens.tobytes()], \
            "crash_restore: stream drifted across snapshot/restore"
    st = sess2.stats_snapshot()
    assert st["restores"] == 1 and st["restore_recompute_tokens"] > 0
    assert st["pages_quarantined"] >= 1, \
        "crash_restore: corrupted page was not quarantined"
    assert st["preemptions"] >= 1 and st["failed"] == 0
    total_tokens = int(sum(len(r.out) for r in done))
    return {
        **{k: cv[k] for k in ("n_slots", "page_size", "prompt_len",
                              "max_new", "snapshot_after_steps")},
        "n_requests": cv["n_requests"],
        "total_tokens": total_tokens,
        "wall_s": round(wall_s, 4),                     # informational
        "snapshot_bytes": snapshot_bytes,               # informational
        "decode_steps": st["decode_steps"],
        **_percentile_metrics(st),                      # informational
        **_dispatch_metrics(st, total_tokens),
        # deterministic recovery-cost counters (gated never-grow in CI)
        "restores": st["restores"],
        "restore_recompute_tokens": st["restore_recompute_tokens"],
        "pages_quarantined": st["pages_quarantined"],
        "nonfinite_logits": st["nonfinite_logits"],
        "double_release": st["double_release"],
        "preemptions": st["preemptions"],
        "recompute_tokens": st["recompute_tokens"],
        "failed": st["failed"],
        "completed": st["completed"],
        "page_high_water": st["page_high_water"],
        "peak_live_tokens": st["peak_live_tokens"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args(argv)

    from repro.configs import get_smoke
    from repro.serve import Engine, ServeConfig

    cfg = get_smoke(args.arch)
    common = dict(max_seq=MAX_SEQ, n_slots=N_SLOTS, temperature=0.0,
                  eos_id=-1)                     # deterministic lengths
    eng_paged = Engine(cfg, ServeConfig(kv_layout="paged",
                                        page_size=PAGE_SIZE, **common))
    eng_dense = Engine(cfg, ServeConfig(kv_layout="dense", **common))
    eng_dense.params = eng_paged.params          # identical token streams

    mixes: Dict[str, Dict] = {}
    for name, lengths, max_new in MIXES:
        paged = bench_mix(eng_paged, cfg, name, lengths, max_new)
        dense = bench_mix(eng_dense, cfg, name, lengths, max_new)
        assert paged["total_tokens"] == dense["total_tokens"]
        # apples-to-apples residency: both layouts must see the same live-
        # token peak (dense used to report 0 — satellite fix)
        assert paged["peak_live_tokens"] == dense["peak_live_tokens"] > 0
        mixes[name] = {"paged": paged, "dense": dense}
        print(f"{name}: paged peak {paged['paged_peak_tokens']} tokens "
              f"(dense pins {paged['dense_equiv_tokens']}), "
              f"pages/token {paged['pages_per_token']:.3f}, "
              f"{paged['admission_deferrals']} deferrals, "
              f"{paged['decode_steps']} decode steps in "
              f"{paged['decode_dispatches']} dispatches "
              f"({paged['tokens_per_dispatch']:.1f} tok/dispatch), "
              f"latency p50/p95/p99 {paged.get('latency_s_p50')}/"
              f"{paged.get('latency_s_p95')}/"
              f"{paged.get('latency_s_p99')} s")

    overload = bench_overload(cfg)
    mixes["overload"] = {"paged": overload}
    print(f"overload: {overload['preemptions']} preemptions "
          f"({overload['recompute_tokens']} recompute tokens), "
          f"{overload['rejected']} rejected, "
          f"{overload['completed']} completed on "
          f"{overload['n_pages']} pages")

    router = bench_router(cfg)
    mixes["router_kill"] = {"paged": router}
    print(f"router_kill: {router['n_replicas']} replicas, "
          f"{router['migrations']} migrations after "
          f"{router['replica_faults']} replica fault, "
          f"{router['shed']} shed at queue_limit "
          f"{router['queue_limit']}, {router['retries_exhausted']} "
          f"retry-budget exhaustions, per-replica page high-water "
          f"{router['page_high_water_per_replica']}")

    crash = bench_crash_restore(cfg)
    mixes["crash_restore"] = {"paged": crash}
    print(f"crash_restore: snapshot {crash['snapshot_bytes']} bytes after "
          f"{crash['snapshot_after_steps']} steps, "
          f"{crash['restore_recompute_tokens']} restore-recompute tokens, "
          f"{crash['pages_quarantined']} pages quarantined, "
          f"{crash['completed']} completed / {crash['failed']} failed")

    peaks = [m["paged"]["paged_peak_tokens"] for m in mixes.values()
             if "paged_peak_tokens" in m["paged"]]
    dense_equiv = N_SLOTS * MAX_SEQ
    out = {
        "meta": {
            "arch": args.arch + "-smoke",
            "max_seq": MAX_SEQ, "n_slots": N_SLOTS,
            "page_size": PAGE_SIZE, "n_requests": N_REQUESTS,
            "python": platform.python_version(),
        },
        "mixes": mixes,
        "summary": {
            "dense_equiv_tokens": dense_equiv,
            "paged_peak_tokens_max": max(peaks),
            "paged_vs_dense_residency": round(max(peaks) / dense_equiv, 4),
            "mixed_length_paged_peak": mixes["mixed_length"]["paged"][
                "paged_peak_tokens"],
            "pages_per_token_worst": max(
                m["paged"]["pages_per_token"] for m in mixes.values()
                if "pages_per_token" in m["paged"]),
            "mixed_length_tokens_per_dispatch": mixes["mixed_length"][
                "paged"]["tokens_per_dispatch"],
            "decode_dispatches_total": sum(
                m["paged"]["decode_dispatches"] for m in mixes.values()),
            # informational latency distribution of the mixed-length mix
            # (p50/p95/p99 from the session's metrics histograms)
            "mixed_length_latency_percentiles": {
                k: v for k, v in mixes["mixed_length"]["paged"].items()
                if k.startswith(("latency_s_p", "queue_s_p",
                                 "prefill_s_p"))},
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {args.out}")
    # acceptance: paged residency <= dense on every mix, strictly lower on
    # the mixed-length mix
    if max(peaks) > dense_equiv:
        print("# FAIL: paged residency exceeds dense", file=sys.stderr)
        return 1
    if mixes["mixed_length"]["paged"]["paged_peak_tokens"] >= dense_equiv:
        print("# FAIL: mixed-length mix shows no paging win",
              file=sys.stderr)
        return 1
    # acceptance (ISSUE 8): the fused loop must amortize ≥4 decode steps
    # per dispatch on the mixed-length mix — the stepwise engine ran at
    # exactly 1, so this is the ≥4× fewer-dispatches-per-token bar
    if mixes["mixed_length"]["paged"]["tokens_per_dispatch"] < 4.0:
        print("# FAIL: fused decode loop amortizes < 4 decode steps per "
              "dispatch on mixed_length", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
