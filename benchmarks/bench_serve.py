"""Serving benchmark: mixed-length prompt mixes through the paged engine.

    PYTHONPATH=src:. python benchmarks/bench_serve.py --out BENCH_serve.json

Per request mix it serves the queue through the **paged** engine and the
**dense** engine (same smoke model, greedy so token streams are identical)
and records per-request latency/queue/prefill timings plus the paging
counters from ``Engine.paging_stats``: page high-water mark, fragmentation
at peak, admission deferrals, and the derived

* ``paged_peak_tokens``  — high-water pages × page_size, the residency a
  right-sized pool needs (the acceptance metric: ≤ dense everywhere,
  strictly lower on mixed-length mixes), and
* ``pages_per_token``    — paged_peak_tokens / peak live tokens ≥ 1.0, the
  internal-fragmentation overhead of page granularity.

The page metrics are **deterministic plan properties** of the request mix
(greedy sampling, ``eos_id=-1`` so generation lengths are fixed): the CI
gate (``check_bench_regression.py --serve-baseline/--serve-new``) bounds
them exactly — pages-per-token and the high-water mark may never grow —
while wall-clock timings are informational only, so the gate cannot flake
on a loaded runner (the PR 3 determinism lesson).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import Dict

import numpy as np

# request mixes: (name, prompt lengths cycled over `requests`, max_new)
MIXES = (
    ("uniform_short", (16,), 8),
    ("uniform_long", (48,), 8),
    ("mixed_length", (8, 48, 16, 64, 24, 8), 8),
    ("mixed_budget", (12, 12, 12), 16),
)
MAX_SEQ = 96
N_SLOTS = 4
PAGE_SIZE = 8
N_REQUESTS = 12


def _requests(cfg, lengths, max_new, n):
    from repro.serve import Request
    rng = np.random.default_rng(0)
    return [Request(tokens=rng.integers(0, cfg.vocab,
                                        (lengths[i % len(lengths)],)
                                        ).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def bench_mix(eng, cfg, name, lengths, max_new) -> Dict:
    reqs = _requests(cfg, lengths, max_new, N_REQUESTS)
    t0 = time.time()
    eng.serve(reqs)
    wall_s = time.time() - t0
    assert all(r.done for r in reqs), f"{name}: unfinished requests"
    lat = np.array([r.latency_s for r in reqs])
    st = dict(eng.paging_stats)
    total_tokens = int(sum(len(r.out) for r in reqs))
    row = {
        "lengths": list(lengths),
        "max_new_tokens": max_new,
        "n_requests": N_REQUESTS,
        "total_tokens": total_tokens,
        # informational (machine-speed dependent; NOT gated)
        "wall_s": round(wall_s, 4),
        "tok_per_s": round(total_tokens / wall_s, 2),
        "latency_s_mean": round(float(lat.mean()), 4),
        "latency_s_max": round(float(lat.max()), 4),
        "queue_s_max": round(max(r.queue_s for r in reqs), 4),
        "decode_steps": st["decode_steps"],
    }
    if st["kv_layout"] == "paged":
        peak_live = max(st["peak_live_tokens"], 1)
        row.update({
            # deterministic plan properties (gated exactly in CI)
            "page_size": st["page_size"],
            "page_high_water": st["page_high_water"],
            "paged_peak_tokens": st["paged_peak_tokens"],
            "dense_equiv_tokens": st["dense_equiv_tokens"],
            "peak_live_tokens": st["peak_live_tokens"],
            "pages_per_token": round(st["paged_peak_tokens"] / peak_live, 4),
            "frag_at_high_water": round(st["frag_at_high_water"], 4),
            "admission_deferrals": st["admission_deferrals"],
        })
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args(argv)

    from repro.configs import get_smoke
    from repro.serve import Engine, ServeConfig

    cfg = get_smoke(args.arch)
    common = dict(max_seq=MAX_SEQ, n_slots=N_SLOTS, temperature=0.0,
                  eos_id=-1)                     # deterministic lengths
    eng_paged = Engine(cfg, ServeConfig(kv_layout="paged",
                                        page_size=PAGE_SIZE, **common))
    eng_dense = Engine(cfg, ServeConfig(kv_layout="dense", **common))
    eng_dense.params = eng_paged.params          # identical token streams

    mixes: Dict[str, Dict] = {}
    for name, lengths, max_new in MIXES:
        paged = bench_mix(eng_paged, cfg, name, lengths, max_new)
        dense = bench_mix(eng_dense, cfg, name, lengths, max_new)
        assert paged["total_tokens"] == dense["total_tokens"]
        mixes[name] = {"paged": paged, "dense": dense}
        print(f"{name}: paged peak {paged['paged_peak_tokens']} tokens "
              f"(dense pins {paged['dense_equiv_tokens']}), "
              f"pages/token {paged['pages_per_token']:.3f}, "
              f"{paged['admission_deferrals']} deferrals")

    peaks = [m["paged"]["paged_peak_tokens"] for m in mixes.values()]
    dense_equiv = N_SLOTS * MAX_SEQ
    out = {
        "meta": {
            "arch": args.arch + "-smoke",
            "max_seq": MAX_SEQ, "n_slots": N_SLOTS,
            "page_size": PAGE_SIZE, "n_requests": N_REQUESTS,
            "python": platform.python_version(),
        },
        "mixes": mixes,
        "summary": {
            "dense_equiv_tokens": dense_equiv,
            "paged_peak_tokens_max": max(peaks),
            "paged_vs_dense_residency": round(max(peaks) / dense_equiv, 4),
            "mixed_length_paged_peak": mixes["mixed_length"]["paged"][
                "paged_peak_tokens"],
            "pages_per_token_worst": max(
                m["paged"]["pages_per_token"] for m in mixes.values()),
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {args.out}")
    # acceptance: paged residency <= dense on every mix, strictly lower on
    # the mixed-length mix
    if max(peaks) > dense_equiv:
        print("# FAIL: paged residency exceeds dense", file=sys.stderr)
        return 1
    if mixes["mixed_length"]["paged"]["paged_peak_tokens"] >= dense_equiv:
        print("# FAIL: mixed-length mix shows no paging win",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
