"""CI gate for the trace-export smoke lane (DESIGN.md §13.3).

    PYTHONPATH=src:. python benchmarks/check_trace.py \
        --trace trace_router.json --metrics metrics_router.json

Validates a ``--trace-out`` export from ``repro.launch.serve`` against
the Chrome trace-event schema — required keys on every event, monotonic
timestamps per (pid, tid) track, balanced name-matched B/E duration
stacks, balanced async request lifelines — and cross-checks it against
the run's ``--metrics-json`` dump: every counted migration, preemption,
restore, replica fault/restart, shed, deadline expiry, and page
quarantine must appear as that many trace events, each attributed to the
right replica track.

``--mode exact`` (default) requires event counts to equal the counters —
the router_kill lane, where stats and trace cover the same run.
``--mode at-least`` requires event counts >= the counters — the
crash_restore lane, where counters roll back to the last snapshot on
restore while the continuous trace legitimately keeps the events from
work done (then lost) after that snapshot.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import export as obs_export


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", required=True,
                    help="Chrome trace-event JSON (launch/serve --trace-out)")
    ap.add_argument("--metrics", default="",
                    help="stats JSON (launch/serve --metrics-json) to "
                         "cross-check counters against; omit to only "
                         "schema-validate")
    ap.add_argument("--mode", choices=("exact", "at-least"),
                    default="exact",
                    help="counter cross-check: exact equality, or trace "
                         ">= counter (crash lanes, where restore rolls "
                         "counters back to the last snapshot)")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)
    problems = obs_export.validate_chrome_trace(doc)
    n_events = len(doc.get("traceEvents", ()))

    stats = None
    if args.metrics:
        with open(args.metrics) as f:
            stats = json.load(f)
        problems += obs_export.cross_check_counters(
            doc, stats, mode=args.mode.replace("-", "_"))

    if problems:
        for p in problems:
            print(f"# FAIL: {p}", file=sys.stderr)
        print(f"# {len(problems)} trace problems in {args.trace}",
              file=sys.stderr)
        return 1
    checked = [c for c, _ in obs_export.DEFAULT_COUNTER_EVENTS
               if stats is not None and c in stats]
    print(f"# OK: {args.trace} valid ({n_events} events); "
          f"cross-checked counters: {', '.join(checked) or 'none'} "
          f"({args.mode})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
